"""Mixture-of-Experts FFN with explicit expert-parallel sharding.

Distribution scheme (hardware adaptation — see DESIGN.md §3):

* Expert weights are sharded over the ``model`` mesh axis.  When the expert
  count is smaller than the axis (mixtral: 8 < 16) each expert is *split*
  along ``d_ff`` into ``factor = axis/E`` slices, so the stacked weight
  tensor always has ``E * factor`` shard-able rows and every chip holds
  expert work.  The factor slices produce partial sums that the combine
  psum adds back together.
* Expert weights are additionally FSDP-sharded over ``data`` on the
  ``d_model`` dim and all-gathered per layer inside the shard_map body
  (ZeRO-3 semantics, overlappable by the scheduler).
* Activations enter batch-sharded and model-replicated; each chip
  dispatches its local tokens to its local experts with a capacity-bounded
  scatter (no giant GShard one-hot dispatch tensors), and a single psum
  over ``model`` performs the combine.  In the paper's taxonomy the
  expert-parallel traffic is the **per-thread** class — it follows shard
  ownership — which is exactly why the MoE cells are the
  paper-representative dry-run cells.

With no active mesh the same code runs single-device (smoke tests).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.parallel import context as ctx


def moe_factor(cfg: ModelConfig) -> int:
    """d_ff split factor so experts fill the whole model axis."""
    axis = ctx.axis_size("expert")
    if axis <= cfg.n_experts:
        assert cfg.n_experts % max(axis, 1) == 0, (cfg.n_experts, axis)
        return 1
    assert axis % cfg.n_experts == 0, (cfg.n_experts, axis)
    factor = axis // cfg.n_experts
    assert cfg.d_ff % factor == 0, (cfg.d_ff, factor)
    return factor


def init_moe_params(key: Array, cfg: ModelConfig, dtype) -> dict:
    """Weights stored pre-split: (E * factor, d_model, d_ff / factor), so
    the expert axis always fills the model mesh axis with no runtime
    reshuffle of sharded tensors."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    factor = moe_factor(cfg)
    rows, f_loc = e * factor, f // factor
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d**-0.5
    return {
        "router": (jax.random.normal(k1, (d, e)) * scale).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (rows, d, f_loc)) * scale).astype(dtype),
        "w_up": (jax.random.normal(k3, (rows, d, f_loc)) * scale).astype(dtype),
        "w_down": (jax.random.normal(k4, (rows, f_loc, d)) * f**-0.5).astype(dtype),
    }


def moe_param_specs(cfg: ModelConfig) -> dict:
    # "efsdp" (not "fsdp") so serve-mode remaps of the dense weights leave
    # expert weights data-sharded — a 398B MoE cannot replicate them.
    return {
        "router": (None, None),
        "w_gate": ("expert", "efsdp", None),
        "w_up": ("expert", "efsdp", None),
        "w_down": ("expert", None, "efsdp"),
    }


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = math.ceil(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.n_experts)
    return max(4, min(c, tokens))


def _local_moe(
    cfg: ModelConfig,
    x: Array,  # (T, D) local tokens
    router: Array,  # (D, E)
    w_gate: Array,  # (E_loc, D, F_loc) — this chip's expert slices
    w_up: Array,
    w_down: Array,  # (E_loc, F_loc, D)
    first_expert: Array,  # scalar: global slot id of local slice row 0
    factor: int,
) -> tuple[Array, Array]:
    """Dispatch local tokens to local expert slices; returns the *partial*
    combine (this chip's experts only) plus the load-balancing aux loss."""
    T, D = x.shape
    e_loc = w_gate.shape[0]
    k = cfg.experts_per_token
    C = _capacity(cfg, T)

    logits = (x.astype(jnp.float32)) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux (Switch-style): E * sum_e f_e * p_e.
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((cfg.n_experts,)).at[top_i.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.n_experts * jnp.sum(me * ce)

    # §Perf iteration c2: combine in the compute dtype.  Multiplying bf16
    # expert outputs by the f32 gate promoted every expert-matmul cotangent
    # AND the shard_map input cotangent's psum to f32 — the dominant
    # all-reduce of the MoE train cells.  Gate precision is preserved in
    # the f32 routing math; only the combine product is bf16.
    out = jnp.zeros((T, D), x.dtype)
    for s in range(e_loc):
        expert_id = (first_expert + s) // factor  # global expert this slot serves
        sel = (top_i == expert_id).astype(jnp.float32)  # (T, k)
        gate = (sel * top_p).sum(axis=-1)  # combine weight per token
        mask = gate > 0.0
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1  # position within expert
        keep = mask & (pos < C)
        slot = jnp.where(keep, pos, C)  # C = overflow bin

        buf = jnp.zeros((C + 1, D), x.dtype).at[slot].add(
            jnp.where(keep[:, None], x, 0.0)
        )
        h = jax.nn.silu(buf @ w_gate[s]) * (buf @ w_up[s])  # (C+1, F_loc)
        y = h @ w_down[s]  # (C+1, D) — partial over d_ff when factor > 1
        out = out + jnp.where(
            keep[:, None], y[slot] * gate.astype(y.dtype)[:, None], 0.0
        )
    return out, aux


def moe_ffn_a2a(cfg: ModelConfig, p: dict, x: Array) -> tuple[Array, Array]:
    """True expert parallelism with all-to-all dispatch (beyond-paper
    extension; see EXPERIMENTS.md §Perf cell c).

    Tokens enter sequence-sharded over the ``model`` axis (each chip
    routes only its S/16 slice — no duplicated dispatch compute), are
    exchanged with a capacity-bounded ``all_to_all`` to the chips owning
    their experts (gates ride along as payload), processed, and exchanged
    back.  In the paper's taxonomy this moves the MoE traffic from the
    Interleaved class (the gather-EP psum ring) into the **Per-thread**
    class — traffic proportional to shard ownership — which is exactly the
    class split the mesh signature's asymmetric profiling identifies.

    Requires factor == 1 (experts >= model axis): qwen3 (128e), jamba (16e).
    """
    mesh = ctx.current_mesh()
    B, S, D = x.shape
    assert moe_factor(cfg) == 1, "a2a path needs n_experts >= model axis"
    if mesh is None:
        return moe_ffn(cfg, p, x)  # single device: same math, no exchange

    batch_axes = ctx.divisible_batch_axes(B) or None
    fsdp_axes = ctx.physical_axes("efsdp")
    ep_axis = ctx.physical_axes("expert")[0]
    n_shards = mesh.shape[ep_axis]
    e_loc = cfg.n_experts // n_shards
    assert S % n_shards == 0, (S, n_shards)
    k = cfg.experts_per_token

    def body(xb, router, wg, wu, wd):
        if fsdp_axes:
            wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axes, axis=2, tiled=True)
        bl, sl, dl = xb.shape
        t_loc = bl * sl
        xt = xb.reshape(t_loc, dl)
        # local routing of the local token slice only
        logits = xt.astype(jnp.float32) @ router  # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((cfg.n_experts,)).at[top_i.reshape(-1)].add(1.0) / (t_loc * k)
        aux = cfg.n_experts * jnp.sum(me * ce)

        # per destination shard: which tokens go there + their local-expert gates
        c_send = max(4, math.ceil(cfg.capacity_factor * t_loc * k / n_shards))
        send = jnp.zeros((n_shards, c_send, dl + e_loc), xb.dtype)
        slots = []
        for j in range(n_shards):
            on_j = (top_i // e_loc) == j  # (T_loc, k)
            gates = jnp.zeros((t_loc, e_loc), jnp.float32)
            gates = gates.at[
                jnp.arange(t_loc)[:, None], jnp.where(on_j, top_i % e_loc, 0)
            ].add(jnp.where(on_j, top_p, 0.0))
            mask = on_j.any(axis=1)
            pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
            keep = mask & (pos < c_send)
            slot = jnp.where(keep, pos, c_send - 1)
            payload = jnp.concatenate([xt, gates.astype(xb.dtype)], axis=1)
            send = send.at[j, slot].add(
                jnp.where(keep[:, None], payload, 0.0)
            )
            slots.append((slot, keep))

        recv = jax.lax.all_to_all(
            send[:, None], ep_axis, split_axis=0, concat_axis=0
        )[:, 0].reshape(n_shards * c_send, dl + e_loc)
        rx, rgates = recv[:, :dl], recv[:, dl:].astype(jnp.float32)

        # second-level local dispatch: received tokens -> this chip's
        # experts via the same capacity-bounded scatter (no dense waste)
        r_tokens = n_shards * c_send
        c2 = max(4, math.ceil(cfg.capacity_factor * r_tokens / e_loc))
        y = jnp.zeros((r_tokens, dl), xb.dtype)
        for e in range(e_loc):
            gate_e = rgates[:, e]
            mask = gate_e > 0.0
            pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
            keep = mask & (pos < c2)
            slot = jnp.where(keep, pos, c2)
            buf = jnp.zeros((c2 + 1, dl), xb.dtype).at[slot].add(
                jnp.where(keep[:, None], rx, 0.0)
            )
            h = jax.nn.silu(buf @ wg[e]) * (buf @ wu[e])
            ye = h @ wd[e]
            y = y + jnp.where(
                keep[:, None], ye[slot] * gate_e[:, None].astype(xb.dtype), 0.0
            )

        back = jax.lax.all_to_all(
            y.reshape(n_shards, c_send, dl)[:, None],
            ep_axis,
            split_axis=0,
            concat_axis=0,
        )[:, 0]  # (n_shards, c_send, D): slice j = my tokens' outputs from shard j
        out = jnp.zeros((t_loc, dl), xb.dtype)
        for j, (slot, keep) in enumerate(slots):
            out = out + jnp.where(keep[:, None], back[j][slot], 0.0)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        aux = jax.lax.pmean(aux, ep_axis)
        return out.reshape(bl, sl, dl), aux

    seq_sharded = jax.lax.with_sharding_constraint(
        x,
        jax.sharding.NamedSharding(
            mesh, P(batch_axes, ep_axis, None)
        ),
    )
    fsdp_spec = fsdp_axes[0] if len(fsdp_axes) == 1 else (fsdp_axes or None)
    out, aux = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, ep_axis, None),
            P(None, None),
            P(ep_axis, fsdp_spec, None),
            P(ep_axis, fsdp_spec, None),
            P(ep_axis, None, fsdp_spec),
        ),
        out_specs=(P(batch_axes, ep_axis, None), P()),
        check_vma=False,
    )(seq_sharded, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = jax.lax.with_sharding_constraint(
        out, jax.sharding.NamedSharding(mesh, P(batch_axes, None, None))
    )
    return out, aux


def _local_moe_sharded_weights(
    cfg: ModelConfig,
    x: Array,  # (T, D) — T is tiny (decode)
    router: Array,
    w_gate: Array,  # (E_loc, D/f, F_loc) — FSDP shard, NOT gathered
    w_up: Array,
    w_down: Array,  # (E_loc, F_loc, D/f)
    first_expert: Array,
    factor: int,
    fsdp_axes: tuple[str, ...],
) -> tuple[Array, Array]:
    """Decode-time expert compute against FSDP weight shards (§Perf d1):
    at one token per sequence, gathering expert weights moves GBs to
    multiply KBs.  Instead contract the local D-slice, psum the (tiny)
    (C, F) partials, and finish with a tiny activation all-gather — zero
    weight movement.  The paper's placement insight inverted: move the
    data to the memory, not the memory to the data."""
    T, D = x.shape
    e_loc = w_gate.shape[0]
    k = cfg.experts_per_token
    C = _capacity(cfg, T)
    n_f = 1
    for a in fsdp_axes:
        n_f *= compat.axis_size(a)
    d_loc = D // n_f
    idx = jax.lax.axis_index(fsdp_axes)

    logits = (x.astype(jnp.float32)) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((cfg.n_experts,)).at[top_i.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.n_experts * jnp.sum(me * ce)

    out = jnp.zeros((T, D), x.dtype)
    for s in range(e_loc):
        expert_id = (first_expert + s) // factor
        sel = (top_i == expert_id).astype(jnp.float32)
        gate = (sel * top_p).sum(axis=-1)
        mask = gate > 0.0
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
        keep = mask & (pos < C)
        slot = jnp.where(keep, pos, C)
        buf = jnp.zeros((C + 1, D), x.dtype).at[slot].add(
            jnp.where(keep[:, None], x, 0.0)
        )
        buf_slice = jax.lax.dynamic_slice_in_dim(buf, idx * d_loc, d_loc, 1)
        h = jax.nn.silu(
            jax.lax.psum(buf_slice @ w_gate[s], fsdp_axes)
        ) * jax.lax.psum(buf_slice @ w_up[s], fsdp_axes)  # (C+1, F_loc)
        y_slice = h @ w_down[s]  # (C+1, D/f)
        y = jax.lax.all_gather(y_slice, fsdp_axes, axis=1, tiled=True)
        out = out + jnp.where(
            keep[:, None], y[slot] * gate.astype(y.dtype)[:, None], 0.0
        )
    return out, aux


def moe_ffn(
    cfg: ModelConfig, p: dict, x: Array, *, decode: bool = False
) -> tuple[Array, Array]:
    """MoE FFN over (B, S, D) activations. Returns (out, aux_loss)."""
    mesh = ctx.current_mesh()
    B, S, D = x.shape
    factor = moe_factor(cfg)

    if mesh is None:  # single-device path (smoke tests)
        out, aux = _local_moe(
            cfg,
            x.reshape(B * S, D),
            p["router"],
            p["w_gate"],
            p["w_up"],
            p["w_down"],
            jnp.asarray(0, jnp.int32),
            factor,
        )
        return out.reshape(B, S, D).astype(x.dtype), aux

    batch_axes = ctx.divisible_batch_axes(B) or None
    fsdp_axes = ctx.physical_axes("efsdp")
    ep_axis = ctx.physical_axes("expert")[0]
    e_loc = cfg.n_experts * factor // mesh.shape[ep_axis]
    if decode and fsdp_axes:
        # The no-gather path contracts weight D-shards along the fsdp axes
        # and psums the partials — every fsdp shard must therefore hold the
        # SAME tokens.  Replicating the decode batch costs a ~MB gather of
        # activations vs the GBs of weight gathers it removes.
        batch_axes = tuple(
            a
            for a in (batch_axes if isinstance(batch_axes, tuple) else
                      ((batch_axes,) if batch_axes else ()))
            if a not in fsdp_axes
        ) or None

    def body(xb, router, wg, wu, wd):
        # xb: (B_loc, S, D); w*: (E_loc, D/fsdp, F_loc).
        first = jax.lax.axis_index(ep_axis) * e_loc
        bl, sl, dl = xb.shape
        if fsdp_axes and decode:
            # no-weight-gather path: see _local_moe_sharded_weights
            out, aux = _local_moe_sharded_weights(
                cfg, xb.reshape(bl * sl, dl), router, wg, wu, wd,
                first, factor, fsdp_axes,
            )
        else:
            if fsdp_axes:  # train/prefill: gathers amortized over T tokens
                wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, fsdp_axes, axis=2, tiled=True)
            out, aux = _local_moe(
                cfg, xb.reshape(bl * sl, dl), router, wg, wu, wd, first, factor
            )
        out = jax.lax.psum(out.astype(xb.dtype), ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(bl, sl, dl), aux

    fsdp_spec = fsdp_axes[0] if len(fsdp_axes) == 1 else (fsdp_axes or None)
    out, aux = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(None, None),
            P(ep_axis, fsdp_spec, None),
            P(ep_axis, fsdp_spec, None),
            P(ep_axis, None, fsdp_spec),
        ),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def moe_apply(
    cfg: ModelConfig, p: dict, x: Array, *, decode: bool = False
) -> tuple[Array, Array]:
    """Dispatch on ``cfg.moe_impl`` (gather-EP vs all-to-all EP)."""
    if cfg.moe_impl == "a2a" and moe_factor(cfg) == 1 and not decode:
        return moe_ffn_a2a(cfg, p, x)
    return moe_ffn(cfg, p, x, decode=decode)
