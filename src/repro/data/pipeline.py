"""Data pipeline: deterministic synthetic streams + dry-run input specs.

* ``batch_struct`` builds ShapeDtypeStruct stand-ins for every model input
  of an (arch x shape) cell — the dry-run lowers against these (weak-type
  correct, shardable, zero allocation).
* ``synthetic_batch`` materializes the same structure with deterministic
  contents for smoke tests and the runnable examples.
* ``TokenStream`` is the host-sharded training iterator: each host draws
  its slice of the global batch from a counter-based PRNG, so any host can
  reproduce any step — which is what makes checkpoint/restart and elastic
  re-sharding deterministic (no data-loader state to save beyond the step).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token length for a cell (frontends consume part of the cell's
    sequence budget; enc-dec caps the decoder)."""
    if cfg.is_encoder_decoder:
        return min(cfg.max_target_len, seq_len)
    if cfg.frontend == "vit_patches":
        return seq_len - cfg.frontend_tokens
    return seq_len


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    t = _token_len(cfg, s)
    out = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if cfg.is_encoder_decoder:
        out["enc_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vit_patches":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    return out


def decode_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one decode step's token input."""
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def synthetic_batch(
    cfg: ModelConfig, seq_len: int, batch: int, key: jax.Array, *, train: bool = True
) -> dict:
    t = _token_len(cfg, seq_len)
    k1, k2, k3 = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(k1, (batch, t), 0, cfg.vocab_size, jnp.int32)}
    if train:
        out["labels"] = jnp.concatenate(
            [out["tokens"][:, 1:], jnp.zeros((batch, 1), jnp.int32)], axis=1
        )
    if cfg.is_encoder_decoder:
        out["enc_frames"] = (
            jax.random.normal(k2, (batch, seq_len, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    if cfg.frontend == "vit_patches":
        out["patch_embeds"] = (
            jax.random.normal(k3, (batch, cfg.frontend_tokens, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    return out


@dataclass
class TokenStream:
    """Deterministic, host-sharded synthetic token stream.

    Batch ``step`` on host ``host_id`` is a pure function of
    ``(seed, step, host_id)`` — resuming after a failure or on a different
    host count replays identical data."""

    cfg: ModelConfig
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        key = jax.random.PRNGKey(self.seed)
        key = jax.random.fold_in(key, step)
        key = jax.random.fold_in(key, self.host_id)
        return synthetic_batch(self.cfg, self.seq_len, self.host_batch, key)

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
