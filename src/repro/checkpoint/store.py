"""Topology-independent sharded checkpointing.

Design (DESIGN.md §7):

* Every leaf is saved as one ``.npy`` per *logical shard chunk* (chunked on
  the leading axis) plus a JSON manifest describing the pytree, dtypes and
  chunking — the on-disk layout never references a mesh, so a checkpoint
  written on 512 chips restores onto 256 (elastic re-shard) or onto 1 CPU.
* Commits are atomic: everything is written into ``step_XXXX.tmp/`` and the
  directory is renamed only after the manifest lands.  A crashed writer
  leaves a ``.tmp`` that restore ignores — the previous step stays valid.
* ``AsyncCheckpointer`` moves serialization off the training thread
  (device-to-host happens at save() call; disk writes overlap the next
  steps), bounded to one in-flight save.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

_MANIFEST = "manifest.json"
_TMP_MARK = ".tmp"
_uid = itertools.count()


def _remove_dir_atomic(path: Path, *, attempts: int = 5) -> None:
    """Remove a directory another thread may still be writing into.

    A plain ``rmtree`` races the writer two ways: the writer's ``open``
    fails midway (FileNotFoundError) and ``rmtree`` itself dies with
    ``OSError: Directory not empty`` when a file lands between the listing
    and the ``rmdir``.  Renaming first is atomic — the writer keeps writing
    into the renamed (doomed) directory and never touches the new path —
    after which the remove only needs a retry for files still arriving.
    """
    trash = path.with_name(f"{path.name}.trash-{os.getpid()}-{next(_uid)}")
    try:
        path.rename(trash)
    except FileNotFoundError:
        return  # someone else already cleaned it up
    for i in range(attempts):
        try:
            shutil.rmtree(trash)
            return
        except FileNotFoundError:
            return
        except OSError:
            if i == attempts - 1:
                raise
            time.sleep(0.05 * (i + 1))


def _is_native(dtype: np.dtype) -> bool:
    return dtype.kind in "biufc"


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """ml_dtypes (bfloat16, fp8) round-trip as raw same-width uints."""
    if _is_native(arr.dtype):
        return arr
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p.name)
            for p in path
        )
        items.append((key, leaf))
    return items, treedef


def save(directory: str | Path, step: int, tree: Any, *, chunk_mb: int = 512) -> Path:
    """Write one checkpoint synchronously; returns the committed path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    # Unique scratch dir per call: two writers for the same step never share
    # a staging directory, so neither can delete files under the other.
    tmp = directory / f"step_{step:08d}{_TMP_MARK}-{os.getpid()}-{next(_uid)}"
    stale = directory / f"step_{step:08d}{_TMP_MARK}"
    if stale.exists():  # pre-fix layout left by a crashed writer
        _remove_dir_atomic(stale)
    tmp.mkdir(parents=True)

    items, _ = _flatten(tree)
    manifest: dict[str, Any] = {"step": step, "leaves": {}, "time": time.time()}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)
        n_chunks = 1
        if arr.ndim and arr.nbytes > chunk_mb << 20:
            n_chunks = min(arr.shape[0], -(-arr.nbytes // (chunk_mb << 20)))
            while arr.shape[0] % n_chunks:
                n_chunks -= 1
        fname = f"leaf_{i:05d}"
        for c in range(n_chunks):
            lo = arr.shape[0] * c // n_chunks if arr.ndim else 0
            hi = arr.shape[0] * (c + 1) // n_chunks if arr.ndim else 0
            part = arr[lo:hi] if n_chunks > 1 else arr
            np.save(tmp / f"{fname}.{c:03d}.npy", _to_savable(part))
        manifest["leaves"][key] = {
            "file": fname,
            "chunks": n_chunks,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    last_err: OSError | None = None
    try:
        for attempt in range(5):
            if final.exists():
                if attempt and (final / _MANIFEST).exists():
                    # A concurrent writer committed a complete checkpoint
                    # for this step while we were retrying — ours is
                    # redundant.
                    _remove_dir_atomic(tmp)
                    return final
                _remove_dir_atomic(final)
            try:
                tmp.rename(final)  # atomic commit
                return final
            except OSError as e:
                last_err = e  # lost a create/remove race with another writer
        if (final / _MANIFEST).exists():
            _remove_dir_atomic(tmp)
            return final
    except BaseException:
        # Never leak the uniquely-named staging dir: it is invisible to
        # latest_step and no later save would reclaim it.
        with contextlib.suppress(OSError):
            _remove_dir_atomic(tmp)
        raise
    with contextlib.suppress(OSError):
        _remove_dir_atomic(tmp)
    raise OSError(f"could not commit checkpoint {final}") from last_err


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if not (p.is_dir() and p.name.startswith("step_")):
            continue
        suffix = p.name[len("step_"):]
        if not suffix.isdigit():  # .tmp-* staging / .trash-* cleanup dirs
            continue
        if (p / _MANIFEST).exists():
            steps.append(int(suffix))
    return max(steps) if steps else None


def restore(
    directory: str | Path,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore a pytree saved by :func:`save` onto the current topology.

    ``like`` provides the tree structure (e.g. from ``jax.eval_shape``).
    ``shardings`` (same structure, optional) re-shards every leaf onto the
    *current* mesh — this is the elastic-scaling path: the checkpoint knows
    nothing about the mesh it was written from.
    """
    path = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / _MANIFEST).read_text())
    items, treedef = _flatten(like)
    sh_items = None
    if shardings is not None:
        sh_items, _ = _flatten(shardings)

    leaves = []
    for i, (key, leaf_like) in enumerate(items):
        meta = manifest["leaves"][key]
        parts = [
            _from_savable(
                np.load(path / f"{meta['file']}.{c:03d}.npy"), meta["dtype"]
            )
            for c in range(meta["chunks"])
        ]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        assert list(arr.shape) == meta["shape"], key
        if sh_items is not None:
            arr = jax.device_put(arr, sh_items[i][1])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background-thread checkpoint writer, one save in flight."""

    def __init__(self, directory: str | Path):
        self.directory = Path(directory)
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # bound to one in-flight write
        host_tree = jax.tree.map(np.asarray, tree)  # d2h on the caller

        def work():
            save(self.directory, step, host_tree)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
