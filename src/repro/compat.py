"""Version-compat shims for the pinned JAX.

The repo targets the newest JAX API surface, but the container pins
jax 0.4.37 where two spellings differ:

* ``jax.shard_map`` does not exist yet — it lives at
  ``jax.experimental.shard_map.shard_map`` and takes ``check_rep``
  instead of ``check_vma``.
* ``pltpu.CompilerParams`` is still called ``pltpu.TPUCompilerParams``.

Import from here instead of guessing which spelling the runtime has.
"""

from __future__ import annotations

from typing import Any

import jax

try:  # modern spelling (jax >= 0.6)
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """``jax.shard_map`` accepting the modern ``check_vma`` kwarg on every
    supported JAX version."""
    kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(name) -> int:
    """``jax.lax.axis_size`` for JAX versions that predate it.

    ``psum(1, name)`` resolves to a static int inside shard_map on every
    supported version; ``name`` may be a single axis or a tuple (product).
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def tpu_compiler_params(**kwargs: Any):
    """Build ``pltpu.CompilerParams`` (``TPUCompilerParams`` on older JAX)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
