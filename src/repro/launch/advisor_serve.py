"""Load driver + CLI for the placement-advisor service.

Spins up an :class:`~repro.serve.AdvisorService` over the NUMA presets
and drives a mixed query stream against it, printing the per-tier
metrics snapshot (counts, batch histogram, p50/p99 latency, retraces).
The driver functions here are also the engine of
``benchmarks/advisor_serve.py``, which commits qps floors and p99
ceilings to CI.

    PYTHONPATH=src python -m repro.launch.advisor_serve \
        --queries 1000 --pool 32 --hit-fraction 0.8 --workers 4
"""

from __future__ import annotations

import argparse
import itertools
import json
import threading
import time

import numpy as np

from repro.serve import AdvisorService, QuerySignature


def signature_pool(
    n: int,
    *,
    read_bpi: float = 0.6,
    write_bpi: float = 0.2,
    seed: int = 0,
) -> list[QuerySignature]:
    """``n`` deterministic distinct workload signatures: mixes drawn from
    a Dirichlet (interleaved takes the 4th share, scaled so every mix sums
    under 1), rounded so canonicalization keeps them distinct."""
    rng = np.random.default_rng(seed)
    sigs = []
    for _ in range(n):
        read = rng.dirichlet(np.ones(4))[:3] * 0.9
        write = rng.dirichlet(np.ones(4))[:3] * 0.9
        sigs.append(
            QuerySignature(
                tuple(round(float(v), 4) for v in read),
                tuple(round(float(v), 4) for v in write),
                read_bpi,
                write_bpi,
            )
        )
    return sigs


def drive_async(service: AdvisorService, queries) -> tuple[list, float]:
    """Open-loop load: submit the whole stream without waiting (concurrent
    misses coalesce into micro-batches), then drain every future.
    ``queries`` is a list of ``(machine_or_fp, signature, n_threads)``.
    Returns (advice list, wall seconds)."""
    t0 = time.perf_counter()
    futures = [service.submit(m, sig, n) for (m, sig, n) in queries]
    results = [f.result() for f in futures]
    wall = time.perf_counter() - t0
    return results, wall


def drive_threads(
    service: AdvisorService, queries, *, n_workers: int = 4,
    deadline_s: float | None = None,
) -> tuple[list, float]:
    """Closed-loop load: ``n_workers`` threads issue synchronous queries,
    each pulling the next query off a shared counter.  ``deadline_s``
    arms the service's degradation ladder per query (None = wait for the
    exact answer).  Returns (advice list in query order, wall seconds)."""
    results: list = [None] * len(queries)
    counter = itertools.count()

    def worker() -> None:
        while True:
            i = next(counter)
            if i >= len(queries):
                return
            machine, sig, n = queries[i]
            results[i] = service.query(
                machine, sig, n, deadline_s=deadline_s
            )

    threads = [
        threading.Thread(target=worker, name=f"advisor-load-{w}")
        for w in range(n_workers)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return results, wall


def mixed_stream(
    pool: list[QuerySignature],
    fresh: list[QuerySignature],
    search_sigs: list[QuerySignature],
    n_queries: int,
    *,
    sweep_target,
    search_target,
    hit_fraction: float = 0.8,
    search_fraction: float = 0.02,
    seed: int = 1,
) -> list[tuple]:
    """A deterministic shuffled stream mixing cache hits (drawn from
    ``pool``, assumed pre-answered), fresh sweep misses (consumed from
    ``fresh``), and search-tier queries (drawn from ``search_sigs``,
    assumed warmed).  ``*_target`` are ``(machine_or_fp, n_threads)``."""
    rng = np.random.default_rng(seed)
    fresh_iter = iter(fresh)
    stream: list[tuple] = []
    for _ in range(n_queries):
        roll = rng.random()
        if roll < search_fraction:
            sig = search_sigs[int(rng.integers(len(search_sigs)))]
            stream.append((search_target[0], sig, search_target[1]))
        elif roll < search_fraction + (1.0 - hit_fraction - search_fraction):
            sig = next(fresh_iter, None)
            if sig is None:  # fresh supply exhausted -> serve a hit instead
                sig = pool[int(rng.integers(len(pool)))]
            stream.append((sweep_target[0], sig, sweep_target[1]))
        else:
            sig = pool[int(rng.integers(len(pool)))]
            stream.append((sweep_target[0], sig, sweep_target[1]))
    return stream


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--queries", type=int, default=1000)
    parser.add_argument("--pool", type=int, default=32,
                        help="distinct signatures in the hot (cached) set")
    parser.add_argument("--hit-fraction", type=float, default=0.8)
    parser.add_argument("--search-fraction", type=float, default=0.02)
    parser.add_argument("--workers", type=int, default=4)
    parser.add_argument("--max-batch", type=int, default=8)
    parser.add_argument("--max-wait-ms", type=float, default=2.0)
    parser.add_argument("--deadline-ms", type=float, default=None,
                        help="per-query deadline (ms); past it the answer "
                             "comes off the degradation ladder")
    parser.add_argument("--json", type=str, default=None,
                        help="write the metrics snapshot to this path")
    args = parser.parse_args()

    from repro.core.numa import E7_4830_V3, make_machine

    service = AdvisorService(
        max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3
    )
    sweep_fp = service.register(E7_4830_V3)
    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    search_fp = service.register(m16)

    pool = signature_pool(args.pool, seed=0)
    fresh = signature_pool(args.queries, seed=7)
    search_sigs = signature_pool(2, seed=13)

    print("warming up (jit traces + search-tier caches)...")
    service.warmup(sweep_fp, 24)
    for sig in pool:  # pre-answer the hot set
        service.query(sweep_fp, sig, 24)
    for sig in search_sigs:
        service.query(search_fp, sig, 32)
    service.metrics.reset(keep_traces=True)

    stream = mixed_stream(
        pool, fresh, search_sigs, args.queries,
        sweep_target=(sweep_fp, 24), search_target=(search_fp, 32),
        hit_fraction=args.hit_fraction,
        search_fraction=args.search_fraction,
    )
    deadline_s = None if args.deadline_ms is None else args.deadline_ms / 1e3
    results, wall = drive_threads(
        service, stream, n_workers=args.workers, deadline_s=deadline_s
    )
    assert all(r is not None for r in results)

    snap = service.metrics.snapshot()
    snap["qps"] = round(len(stream) / wall, 1)
    snap["wall_s"] = round(wall, 3)
    print(json.dumps(snap, indent=2))
    if args.json and args.json != "-":
        with open(args.json, "w") as fh:
            json.dump(snap, fh, indent=2)
        print(f"wrote {args.json}")
    service.close()


if __name__ == "__main__":
    main()
