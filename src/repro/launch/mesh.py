"""Production mesh construction + per-cell sharding policy.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 = 256 chips per pod, and 2 pods = 512 chips for the
multi-pod dry-run.  The ``pod`` axis carries data parallelism across pods;
FSDP stays *inside* a pod (parameter gathers ride intra-pod ICI, only grad
all-reduce crosses the pod interconnect — see DESIGN.md §7).
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel import context as ctx

GB = 1 << 30

# Serving keeps params replicated over the data axis when the per-chip TP
# shard is comfortably under HBM; larger models add FSDP to serving too.
SERVE_REPLICATION_LIMIT = 6 * GB


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def candidate_mesh_axes(
    n_devices: int,
    *,
    axis_names: tuple[str, str] = ("data", "model"),
    min_model: int = 1,
    max_model: int | None = None,
) -> list[dict[str, int]]:
    """Every 2-axis factorization of ``n_devices`` (model axis between
    ``min_model`` and ``max_model``), in advisor candidate form — the
    enumeration ``advise_mesh_shape`` and the mesh-rank benchmark score."""
    if n_devices < 1:
        raise ValueError("need >= 1 device")
    if max_model is None:
        max_model = n_devices
    outer, inner = axis_names
    out = []
    for model in range(min_model, max_model + 1):
        if n_devices % model:
            continue
        out.append({outer: n_devices // model, inner: model})
    if not out:
        raise ValueError(
            f"no factorization of {n_devices} devices with model axis in "
            f"[{min_model}, {max_model}]"
        )
    return out


def advise_mesh_shape(
    sig,
    n_devices: int,
    *,
    chip=None,
    topology=None,
    axis_names: tuple[str, str] = ("data", "model"),
    min_model: int = 1,
    max_model: int | None = None,
):
    """Rank every 2-axis mesh factorization of ``n_devices`` by predicted
    step time through the shared advisor — scalar roofline by default, the
    routed per-link model when a
    :class:`~repro.core.meshsig.device_topology.DeviceTopology` is given.
    Returns the advisor's sorted :class:`MeshRanking` list (best first)."""
    from repro.core.meshsig.advisor import CHIP_V5E, rank_meshes

    candidates = candidate_mesh_axes(
        n_devices, axis_names=axis_names, min_model=min_model,
        max_model=max_model,
    )
    return rank_meshes(
        sig, candidates, chip=chip or CHIP_V5E, topology=topology
    )


def serve_params_replicated(cfg: ModelConfig) -> bool:
    """True when bf16 params / model-axis fit comfortably per chip."""
    tp = 16
    return cfg.param_count() * 2 / tp <= SERVE_REPLICATION_LIMIT


@contextlib.contextmanager
def cell_context(mesh, cfg: ModelConfig, shape: ShapeConfig):
    """Activate the mesh + the logical-axis policy for one (arch, shape)
    cell: decode-cache layout and the serve-time FSDP decision."""
    overrides = {}
    axis_names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_names)

    if shape.kind in ("decode", "prefill"):
        if not serve_params_replicated(cfg):
            overrides["fsdp"] = ("data",)  # prefill: gathers amortized by T
        else:
            # small enough to replicate over data — dense AND expert weights
            overrides["fsdp"] = ()
            overrides["efsdp"] = ()
    if shape.kind == "decode":
        usable = [a for a in batch_axes if shape.global_batch % mesh.shape[a] == 0]
        # batch dim takes every data-ish axis it divides; the sequence dim
        # takes everything else (long_500k: batch=1 -> seq over all axes).
        cache_batch = tuple(usable) if shape.global_batch > 1 else ()
        seq_axes = tuple(a for a in axis_names if a not in cache_batch)
        overrides["cache_batch"] = cache_batch
        overrides["cache_seq"] = seq_axes
    with ctx.use_mesh(mesh), ctx.use_logical_rules(**overrides):
        yield


def serve_decode_param_shardings(mesh, cfg: ModelConfig):
    """Parameter shardings for big-model decode (§Perf iteration d2):
    dense weights shard their TP dims over model x data (2D TP), so no
    per-token FSDP weight gather ever happens — GSPMD moves the (tiny)
    partial activations instead.  Expert weights keep their data shard
    via "efsdp" (the no-gather MoE decode path).  Scoped to the PARAM
    tree only: activation constraints keep 1D TP."""
    from repro.models import model as M

    with ctx.use_logical_rules(fsdp=(), tp=("model", "data")):
        return tree_shardings(mesh, M.param_specs(cfg))


def _is_spec_leaf(x) -> bool:
    # Spec leaves are PLAIN tuples of logical dims; NamedTuples (KVCache,
    # MambaCache) are containers, not leaves.
    return type(x) is tuple


def tree_shardings(mesh, spec_tree):
    """Logical-dim tuples -> NamedShardings (leaves are tuples of dims)."""

    def to_sharding(dims):
        return NamedSharding(mesh, ctx.resolve(*dims))

    return jax.tree.map(to_sharding, spec_tree, is_leaf=_is_spec_leaf)


def batch_shardings(mesh, struct_tree):
    """Batch inputs: dim 0 over (pod, data) where divisible, else over the
    largest divisible prefix of those axes (replicated when batch=1)."""

    def sh(s):
        if not s.shape:
            return NamedSharding(mesh, P())
        b = s.shape[0]
        axes = []
        prod = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names and b % (prod * mesh.shape[a]) == 0:
                axes.append(a)
                prod *= mesh.shape[a]
        spec = P(tuple(axes) if axes else None, *([None] * (len(s.shape) - 1)))
        return NamedSharding(mesh, spec)

    return jax.tree.map(sh, struct_tree)
