import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(**input_specs(arch))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

plus a parse of the post-partitioning HLO for collective bytes (the
roofline's third term and the meshsig performance counters).  Results are
cached as JSON under ``benchmarks/dryrun_results/`` so reruns only compile
missing cells.

NOTE: the two XLA_FLAGS lines above MUST stay the first statements — jax
locks the device count on first initialization.
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_supported, get_config, list_configs
from repro.core.meshsig.hlo_counters import analyze_hlo
from repro.data.pipeline import batch_struct, decode_struct
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import context as ctx

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _key_struct():
    k = jax.random.PRNGKey(0)
    return jax.ShapeDtypeStruct(k.shape, k.dtype)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> tuple:
    """Build (jitted_fn, arg_structs, arg_shardings, out_shardings, meta)."""
    meta: dict = {}
    if shape.kind == "train":
        param_structs = jax.eval_shape(partial(M.init_params, cfg), _key_struct())
        opt_structs = jax.eval_shape(
            partial(adamw.init, moment_dtype=cfg.moment_dtype), param_structs
        )
        params_sh = mesh_lib.tree_shardings(mesh, M.param_specs(cfg))
        opt_sh = adamw.AdamWState(step=_replicated(mesh), m=params_sh, v=params_sh)
        b_structs = batch_struct(cfg, shape)
        b_sh = mesh_lib.batch_shardings(mesh, b_structs)
        step_struct = jax.ShapeDtypeStruct((), jnp.int32)
        accum = steps.auto_accum(cfg, shape.global_batch)
        meta["accum"] = accum
        fn = steps.make_train_step(cfg, accum=accum)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, opt_sh, b_sh, _replicated(mesh)),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        args = (param_structs, opt_structs, b_structs, step_struct)
    elif shape.kind == "prefill":
        serve_params = jax.eval_shape(
            lambda k: M.cast_for_compute(cfg, M.init_params(cfg, k)), _key_struct()
        )
        params_sh = mesh_lib.tree_shardings(mesh, M.param_specs(cfg))
        b_structs = batch_struct(cfg, shape)
        b_sh = mesh_lib.batch_shardings(mesh, b_structs)
        fn = steps.make_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(params_sh, b_sh), out_shardings=None)
        args = (serve_params, b_structs)
    else:  # decode
        serve_params = jax.eval_shape(
            lambda k: M.cast_for_compute(cfg, M.init_params(cfg, k)), _key_struct()
        )
        if mesh_lib.serve_params_replicated(cfg):
            params_sh = mesh_lib.tree_shardings(mesh, M.param_specs(cfg))
        else:  # §Perf d2: 2D-TP weights, zero per-token gathers
            params_sh = mesh_lib.serve_decode_param_shardings(mesh, cfg)
        cache_structs = jax.eval_shape(
            partial(M.init_cache, cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        cache_sh = mesh_lib.tree_shardings(mesh, M.cache_specs(cfg))
        d = decode_struct(cfg, shape)
        tok_sh = mesh_lib.batch_shardings(mesh, {"tokens": d["tokens"]})["tokens"]
        next_sh = mesh_lib.batch_shardings(
            mesh, {"n": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}
        )["n"]
        fn = steps.make_decode_step(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, tok_sh, _replicated(mesh)),
            out_shardings=(next_sh, None, cache_sh),
            donate_argnums=(1,),
        )
        args = (serve_params, cache_structs, d["tokens"], d["pos"])
    return jitted, args, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, force: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        cached = json.loads(out_path.read_text())
        if cached.get("status") != "failed":  # failures always retry
            return cached

    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "family": cfg.family,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, why = cell_supported(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["skip_reason"] = why
        _write(out_path, record)
        return record

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        with mesh_lib.cell_context(mesh, cfg, shape):
            t0 = time.time()
            jitted, args, meta = lower_cell(cfg, shape, mesh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        record.update(meta)
        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)

        try:
            mem = compiled.memory_analysis()
            record["memory"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "alias_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # pragma: no cover
            record["memory"] = {"error": str(e)}

        try:
            cost = compiled.cost_analysis()
            record["cost"] = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
        except Exception as e:  # pragma: no cover
            record["cost"] = {"error": str(e)}

        try:
            hlo = compiled.as_text()
            record["hlo_chars"] = len(hlo)
            analysis = analyze_hlo(hlo)
            del hlo
            record["hlo_flops"] = analysis.flops  # per device, trip-multiplied
            record["hlo_bytes"] = analysis.hbm_bytes  # fusion-idealized model
            record["hlo_bytes_raw"] = analysis.hbm_bytes_raw  # upper bound
            record["unknown_trip_loops"] = analysis.unknown_trip_loops
            record["collectives"] = analysis.collective_summary()
        except Exception as e:  # pragma: no cover
            record["collectives"] = {"error": str(e)}

        record["status"] = "ok"
    except Exception as e:
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(out_path, record)
    return record


def _write(path: Path, record: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, force=args.force)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    flops = rec.get("hlo_flops", 0)
                    link = rec.get("collectives", {}).get("link_bytes_total", 0)
                    extra = f"flops/dev={flops:.3e} link_bytes/dev={link:.3e} compile={rec.get('compile_s')}s"
                elif status == "failed":
                    n_fail += 1
                    extra = rec.get("error", "")[:200]
                elif status == "skipped":
                    extra = rec.get("skip_reason", "")
                print(
                    f"[{time.strftime('%H:%M:%S')}] {arch:24s} {shape_name:12s} {mesh_kind:6s} "
                    f"{status:8s} ({time.time()-t0:6.1f}s) {extra}",
                    flush=True,
                )
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
