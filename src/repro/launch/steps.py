"""Step functions: training (with gradient accumulation) and serving.

``make_train_step(cfg)`` returns the jit-able function
``(params, opt_state, batch, step) -> (params, opt_state, metrics)``.
Gradient accumulation scans over microbatches so per-device activation
memory stays at one microbatch regardless of the global batch; grads
accumulate in fp32.  An optional int8 error-feedback compressed all-reduce
path lives in ``repro.parallel.compression`` (see DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import context as ctx


def auto_accum(cfg: ModelConfig, global_batch: int, *, target_micro: int = 2) -> int:
    """Pick the accumulation factor so each device sees ~``target_micro``
    sequences per microbatch."""
    dp = ctx.axis_size("batch")
    local = max(1, global_batch // dp)
    accum = max(1, local // target_micro)
    while global_batch % (accum) or (global_batch // accum) % dp:
        accum -= 1  # keep both the microbatch and its dp-split integral
    return max(1, accum)


def make_train_step(
    cfg: ModelConfig,
    *,
    accum: int = 1,
    lr_schedule: Callable[[Array], Array] | None = None,
    max_grad_norm: float = 1.0,
) -> Callable:
    if lr_schedule is None:
        lr_schedule = adamw.cosine_schedule(3e-4, 200, 10_000)

    def loss(params, micro):
        l, parts = M.loss_fn(cfg, params, micro)
        return l, parts

    def train_step(params, opt_state, batch, step):
        # §Perf iteration c1: pin the gradient accumulator to the params'
        # FSDP/TP sharding.  Unconstrained, GSPMD all-reduces the FULL f32
        # gradient tree every microbatch (the dominant collective in every
        # train cell); constrained, each micro's sync is a reduce-scatter
        # onto the shard and the carry never materializes unsharded.
        from repro.launch import mesh as mesh_lib
        from repro.models import model as M
        from repro.parallel import context as ctx

        mesh = ctx.current_mesh()
        grad_shardings = (
            mesh_lib.tree_shardings(mesh, M.param_specs(cfg)) if mesh else None
        )

        def pin(g):
            if grad_shardings is None:
                return g
            return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

        # §Perf iteration c3: hoist the FSDP parameter all-gather out of the
        # microbatch loop.  Unconstrained, every micro-step re-gathers the
        # bf16 weights over the data axis (accum x the bytes); pinning the
        # compute-dtype copy to a TP-only sharding materializes it once per
        # step (HBM cost: params/model_axis bf16 per chip).
        params_compute = None
        if mesh is not None and accum > 1:
            with ctx.use_logical_rules(fsdp=()):
                gathered_sh = mesh_lib.tree_shardings(mesh, M.param_specs(cfg))

            def gather_once(params):
                cast = M.cast_for_compute(cfg, params)
                return jax.tree.map(
                    jax.lax.with_sharding_constraint, cast, gathered_sh
                )

            params_compute = gather_once

        if accum == 1:
            (l, parts), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
            grads = pin(grads)
        else:
            def split(x):
                return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

            micros = jax.tree.map(split, batch)
            # loop-invariant gathered copy (c3): lives outside the scan
            loss_params = params_compute(params) if params_compute else params

            def micro_step(acc, micro):
                g_acc, l_acc = acc
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(
                    loss_params, micro
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                g_acc = pin(g_acc)  # per-micro sync lands as reduce-scatter
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else jnp.zeros(p.shape, p.dtype),
                params,
            )
            (grads, l_sum), _ = jax.lax.scan(
                micro_step, (g0, jnp.zeros(())), micros
            )
            grads = jax.tree.map(lambda g: g / accum, grads)
            l = l_sum / accum
            parts = {}

        grads, gnorm = adamw.clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(step)
        new_params, new_opt = adamw.update(grads, opt_state, params, lr=lr)
        metrics = {"loss": l, "grad_norm": gnorm, "lr": lr, **parts}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = M.decode_step(cfg, params, cache, tokens, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return serve_step
