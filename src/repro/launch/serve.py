"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --batch 4 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel import context as ctx


def generate(cfg, params, prompts, max_len, gen_tokens):
    """Teacher-forced prefill through the decode path (fills the cache),
    then greedy generation."""
    B, P = prompts.shape
    cache = M.init_cache(cfg, B, max_len, jnp.bfloat16)
    step = jax.jit(steps_lib.make_decode_step(cfg), donate_argnums=(1,))
    tok = prompts[:, :1]
    out = [tok[:, 0]]
    nxt = None
    for t in range(P + gen_tokens - 1):
        nxt, _, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = prompts[:, t + 1 : t + 2] if t + 1 < P else nxt[:, None]
        out.append(tok[:, 0])
    return jnp.stack(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use an LM arch for this demo (enc-dec needs audio frames)")
    mesh = None if args.mesh == "none" else make_production_mesh(multi_pod=args.mesh == "multi")

    with ctx.use_mesh(mesh):
        params = M.cast_for_compute(cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
        t0 = time.time()
        seqs = generate(cfg, params, prompts, args.prompt_len + args.gen, args.gen)
        seqs.block_until_ready()
        dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"generated {n_new} tokens in {dt:.1f}s ({n_new/dt:.1f} tok/s)")
    print("first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
