"""Training launcher.

CPU-scale smoke runs use reduced configs; on a real pod the same entry
point takes ``--mesh single|multi`` and the full config.  Fault tolerance:
checkpoints every ``--save-every`` steps (async), resumes automatically,
EWMA straggler monitoring, deterministic data replay.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import TokenStream
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, tree_shardings
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import context as ctx
from repro.runtime.fault_tolerance import StragglerMonitor, TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--layers", type=int, default=0, help="override depth")
    ap.add_argument("--vocab", type=int, default=0, help="override vocab")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses

    overrides = {}
    if args.d_model:
        h = max(args.d_model // 64, 1)
        overrides.update(
            d_model=args.d_model, d_ff=4 * args.d_model,
            n_heads=h, n_kv_heads=max(h // 4, 1), d_head=64,
        )
    if args.layers:
        overrides["n_layers"] = args.layers * cfg.group_size
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-custom", **overrides)
    mesh = None if args.mesh == "none" else make_production_mesh(multi_pod=args.mesh == "multi")

    with ctx.use_mesh(mesh):
        params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt = adamw.init(params, cfg.moment_dtype)
        if mesh is not None:
            sh = tree_shardings(mesh, M.param_specs(cfg))
            params = jax.tree.map(jax.device_put, params, sh)
            opt = adamw.AdamWState(
                step=opt.step,
                m=jax.tree.map(jax.device_put, opt.m, sh),
                v=jax.tree.map(jax.device_put, opt.v, sh),
            )
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

        schedule = adamw.cosine_schedule(args.lr, max(args.steps // 10, 1), args.steps)
        train_step = jax.jit(
            steps_lib.make_train_step(cfg, accum=args.accum, lr_schedule=schedule),
            donate_argnums=(0, 1),
        )
        stream = TokenStream(cfg, args.seq, args.batch, seed=args.seed)

        def step_fn(state, step):
            params, opt = state
            batch = stream.batch_at(step)
            params, opt, metrics = train_step(
                params, opt, batch, jnp.asarray(step, jnp.int32)
            )
            return (params, opt), {k: float(v) for k, v in metrics.items()}

        loop = TrainLoop(
            step_fn=step_fn,
            ckpt_dir=args.ckpt_dir,
            save_every=args.save_every,
            monitor=StragglerMonitor(),
        )
        t0 = time.time()
        (params, opt), step, history = loop.run((params, opt), args.steps)
        dt = time.time() - t0

    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(
        f"done: step={step} loss {first:.3f} -> {last:.3f} "
        f"({dt:.1f}s, {dt/max(len(history),1):.2f}s/step, "
        f"stragglers={len(loop.monitor.flagged)})"
    )


if __name__ == "__main__":
    main()
