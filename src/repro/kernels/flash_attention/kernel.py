"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the classic GPU flash algorithm (DESIGN.md §3):

* Tiling targets the MXU/VMEM hierarchy rather than SM shared memory: the
  grid is (batch, q_head, q_block) with the KV walk as an innermost
  *arbitrary* grid dimension; (m, l, acc) live in VMEM scratch that
  persists across the KV steps of one q block (output revisiting), so the
  working set is exactly (block_q x d_head) fp32 + two (block_q,) rows.
* GQA is native: the k/v BlockSpec index maps q-head h to kv-head
  ``h // group``, so K/V tiles are fetched once per kv head — no
  ``jnp.repeat`` materialization like the XLA fallback path needs.
* block shapes default to MXU-aligned (multiples of 128 on the matmul
  dims); d_head rides whole (128 or 256 for every assigned arch).
* sliding-window / causal masking is iota-based per tile; fully-masked
  tiles short-circuit via ``pl.when`` (no MXU work issued).

Validated against ``ref.attention_ref`` in interpret mode (CPU container);
the TPU path is the compile target.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

_NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, 1, bq, dh)
    k_ref,  # (1, 1, bkv, dh)
    v_ref,  # (1, 1, bkv, dh)
    o_ref,  # (1, 1, bq, dh)
    m_ref,  # VMEM scratch (bq,)
    l_ref,  # VMEM scratch (bq,)
    acc_ref,  # VMEM scratch (bq, dh)
    *,
    scale: float,
    causal: bool,
    window: int,
    logit_cap: float,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
    q_offset: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    cols = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    # Tile-level visibility: skip tiles that the causal/window pattern
    # fully masks (saves the MXU issue entirely).
    row_min = q_offset + iq * block_q
    row_max = row_min + block_q - 1
    col_min = ikv * block_kv
    col_max = col_min + block_kv - 1
    live = True
    if causal:
        live = col_min <= row_max
    if window:
        live = jnp.logical_and(live, col_max > row_min - window)

    @pl.when(live)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if logit_cap > 0.0:
            logits = logit_cap * jnp.tanh(logits / logit_cap)
        ok = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, cols <= rows)
        if window:
            ok = jnp.logical_and(ok, cols > rows - window)
        logits = jnp.where(ok, logits, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ikv == n_kv_blocks - 1)
    def finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: Array,  # (B, H, Sq, dh)
    k: Array,  # (B, Kv, Skv, dh)
    v: Array,  # (B, Kv, Skv, dh)
    *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> Array:
    B, H, Sq, dh = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    assert H % Kv == 0
    group = H // Kv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv, block_q, block_kv)
    nq, nkv = Sq // block_q, Skv // block_kv
    q_offset = Skv - Sq  # right-aligned queries (prefill continuation)

    grid = (B, H, nq, nkv)
    kernel = functools.partial(
        _kernel,
        scale=dh**-0.5,
        causal=causal,
        window=window,
        logit_cap=logit_cap,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=nkv,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
