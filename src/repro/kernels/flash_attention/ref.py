"""Pure-jnp oracle for the flash-attention kernel.

Naive direct attention (materialized logits, f32 softmax) — deliberately
the simplest correct implementation, used as the allclose reference for
the Pallas kernel across the shape/dtype sweep.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array


def attention_ref(
    q: Array,  # (B, H, Sq, dh)
    k: Array,  # (B, Kv, Skv, dh)
    v: Array,  # (B, Kv, Skv, dh)
    *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
) -> Array:
    B, H, Sq, dh = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    G = H // Kv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum(
        "bhqd,bhsd->bhqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh**-0.5)
    if logit_cap > 0.0:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    rows = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned queries
    cols = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= cols <= rows
    if window:
        ok &= cols > rows - window
    logits = jnp.where(ok[None, None], logits, -1e30)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqs,bhsd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
