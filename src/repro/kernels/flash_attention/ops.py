"""Jit'd public wrapper for the flash-attention kernel.

Chooses MXU-aligned block sizes from the problem shape, falls back to
interpret mode automatically off-TPU (this container), and exposes the
same (B, S, H, dh) layout the model layer uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels.flash_attention.kernel import flash_attention


def _pick_block(s: int, target: int = 512) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_cap", "interpret")
)
def mha_flash(
    q: Array,  # (B, Sq, H, dh) — model layout
    k: Array,  # (B, Skv, Kv, dh)
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    interpret: bool | None = None,
) -> Array:
    if interpret is None:
        interpret = not on_tpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        logit_cap=logit_cap,
        block_q=_pick_block(q.shape[1]),
        block_kv=_pick_block(k.shape[1]),
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
