"""Selective scan (mamba-1) as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §3): the CUDA kernel's per-thread sequential
recurrence becomes a channel-tiled VMEM-resident scan:

* grid = (batch, d_inner blocks, seq chunks); the chunk dimension is
  *arbitrary* (sequential) and the (block_d, N) state lives in VMEM
  scratch, persisting across chunks — the state never round-trips HBM
  within a sequence;
* channels are independent, so the d_inner grid dimension is embarrassingly
  parallel (and TP shards it across chips before the kernel is entered);
* per chunk, the inputs are (chunk, block_d) tiles — VPU elementwise work
  with an (N)-wide inner broadcast; N = 16 for every assigned SSM arch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(
    dt_ref,  # (1, c, bd)
    a_ref,  # (bd, N)
    b_ref,  # (1, c, N)
    c_ref,  # (1, c, N)
    x_ref,  # (1, c, bd)
    y_ref,  # (1, c, bd)
    h_ref,  # VMEM scratch (bd, N) — persists across chunk steps
    *,
    chunk: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]  # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :]  # (bd,)
        x_t = x_ref[0, t, :]
        b_t = b_ref[0, t, :]  # (N,)
        c_t = c_ref[0, t, :]
        da = jnp.exp(dt_t[:, None] * a)  # (bd, N)
        h = h * da + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = (h * c_t[None, :]).sum(axis=1)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def selective_scan(
    dt: Array,  # (B, S, di) f32
    a: Array,  # (di, N) f32
    b: Array,  # (B, S, N) f32
    c: Array,  # (B, S, N) f32
    x: Array,  # (B, S, di) f32
    *,
    block_d: int = 512,
    chunk: int = 256,
    interpret: bool = False,
) -> Array:
    B, S, di = x.shape
    n = a.shape[1]
    block_d = min(block_d, di)
    chunk = min(chunk, S)
    assert di % block_d == 0 and S % chunk == 0, (di, block_d, S, chunk)
    grid = (B, di // block_d, S // chunk)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, j: (bi, j, d)),
            pl.BlockSpec((block_d, n), lambda bi, d, j: (d, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, j: (bi, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, j: (bi, j, 0)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, j: (bi, j, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda bi, d, j: (bi, j, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dt, a, b, c, x)
