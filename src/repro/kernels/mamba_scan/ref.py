"""Pure-jnp oracle for the selective-scan kernel: naive sequential
recurrence h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t,
y_t = (h_t . C_t)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def selective_scan_ref(
    dt: Array,  # (B, S, di) f32 (post-softplus)
    a: Array,  # (di, N) f32 (negative)
    b: Array,  # (B, S, N) f32
    c: Array,  # (B, S, N) f32
    x: Array,  # (B, S, di) f32
    h0: Array | None = None,  # (B, di, N)
) -> tuple[Array, Array]:
    B, S, di = x.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, di, n), jnp.float32)

    def step(h, inputs):
        dt_t, b_t, c_t, x_t = inputs  # (B, di), (B, N), (B, N), (B, di)
        da = jnp.exp(dt_t[..., None] * a[None])  # (B, di, N)
        h = h * da + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step,
        h0,
        (
            dt.transpose(1, 0, 2),
            b.transpose(1, 0, 2),
            c.transpose(1, 0, 2),
            x.transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2), hT  # (B, S, di), (B, di, N)
