"""Jit'd public wrapper for the selective-scan kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels.mamba_scan.kernel import selective_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(
    dt: Array,
    a: Array,
    b: Array,
    c: Array,
    x: Array,
    *,
    interpret: bool | None = None,
) -> Array:
    if interpret is None:
        interpret = not on_tpu()
    block_d = 512
    di = x.shape[-1]
    while di % block_d:
        block_d //= 2
    chunk = 256
    while x.shape[1] % chunk:
        chunk //= 2
    return selective_scan(
        dt.astype(jnp.float32),
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        c.astype(jnp.float32),
        x.astype(jnp.float32),
        block_d=block_d,
        chunk=chunk,
        interpret=interpret,
    )
